"""Kernel microbenchmarks (interpret/jnp on CPU — correctness-scale only;
wall-times here are NOT TPU numbers, the roofline report covers those).

Reports the plan-level reuse metrics that determine TPU performance
(triples, B-fetch elision / block OMAR, arithmetic intensity) via the
plan/execute API, plus the amortization the API exists for: plan-build
time vs numeric-only execute time on the same pattern.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import timeit
from repro.data.pipeline import SpGEMMValueStream
from repro.kernels import ops
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.formats import COO
from repro.sparse.random import random_block_sparse, suite_matrix
from repro.spgemm import PlanCache, spgemm_plan
from repro.spgemm.persist import PlanStore

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# (matrix, scale, tile, group, batch) for the sharded section: sizes where
# the per-shard working set drops under the batch-fusion knee, so sharding
# buys both parallel shards and bigger fused chunks.
_SHARDED_CASES = (
    ("poisson3Da", 0.03, 16, 4, 32),
    ("2cubes_sphere", 0.008, 16, 4, 32),
)


def run(quiet: bool = False, devices: int = 0, pipeline_depths=(1, 2, 4)):
    data = {}
    print("kernels,case,triples,b_fetches,block_omar_pct,flops,"
          "bytes_streamed,arith_intensity,plan_ms,execute_ms")
    for (m, k, n, da, db, g) in [
        (512, 512, 512, 0.2, 0.2, 2),
        (1024, 512, 1024, 0.1, 0.15, 4),
        (512, 1024, 512, 0.3, 0.3, 8),
    ]:
        bm = bk = bn = 128
        ad = random_block_sparse(m, k, (bm, bk), da, seed=1)
        bd = random_block_sparse(k, n, (bk, bn), db, seed=2)
        cache = PlanCache()

        def build_plan():
            cache.clear()
            return spgemm_plan(ad, bd, tile=(bm, bk, bn), group=g,
                               backend="jnp", cache=cache)

        plan = build_plan()
        rep = plan.report
        flops = 2 * rep.num_triples * bm * bk * bn
        # HBM bytes: A streamed once; B fetched per elided schedule; C
        # panels written once.
        bytes_ = (rep.nnzb_a * bm * bk + rep.b_fetches * bk * bn
                  + rep.n_panels * g * bm * bn) * 4
        ai = flops / bytes_
        # Amortization: full plan build (conversion + symbolic + staging)
        # vs numeric-only execute with fresh values on the cached plan.
        plan_ms = timeit(build_plan, repeats=3, warmup=0) * 1e3
        a_vals = plan.a_pattern.val * 0.5
        b_vals = plan.b_pattern.val * 2.0
        exec_ms = timeit(lambda: plan.execute(a_vals, b_vals),
                         repeats=3, warmup=1) * 1e3
        print(f"kernels,spgemm_{m}x{k}x{n}_g{g},{rep.num_triples},"
              f"{rep.b_fetches},{rep.block_omar:.1f},{flops:.2e},"
              f"{bytes_:.2e},{ai:.1f},{plan_ms:.1f},{exec_ms:.1f}")

    # Plan reuse correctness: fresh values on a cached plan match a fresh
    # dense reference (the serving loop's invariant).
    ad = random_block_sparse(256, 256, (64, 64), 0.3, seed=3)
    bd = random_block_sparse(256, 256, (64, 64), 0.3, seed=4)
    plan = spgemm_plan(ad, bd, tile=64, group=2,
                       backend="pallas_interpret", cache=PlanCache())
    c = plan.execute()
    err = np.abs(c.todense() - ad @ bd).max()
    print(f"kernels,spgemm_plan_interpret_maxerr,{err:.2e}")
    a2 = np.zeros_like(ad)
    a2[plan.a_pattern.row, plan.a_pattern.col] = plan.a_pattern.val * 3.0
    c2 = plan.execute(plan.a_pattern.val * 3.0, None)
    err2 = np.abs(c2.todense() - a2 @ bd).max()
    print(f"kernels,spgemm_plan_reexec_maxerr,{err2:.2e}")

    # Compatibility shim spot-check (ops.spgemm -> cached plan).
    c3 = ops.spgemm(to_bcsv(ad, (64, 64), 2), to_bcsr(bd, (64, 64)),
                    backend="pallas_interpret")
    err3 = np.abs(c3.todense() - ad @ bd).max()
    print(f"kernels,spgemm_ops_shim_maxerr,{err3:.2e}")

    # Batched numeric phase: one vmapped execute_batch call vs a loop of
    # single executes over the same value sets (C = A @ A^T on scaled paper
    # patterns, jnp backend — the serving workload shape).
    print("kernels,batched_case,batch,nnz_per_set,loop_ms,batch_ms,"
          "values_per_s,speedup")
    for name, scale in (("poisson3Da", 0.02), ("2cubes_sphere", 0.003)):
        a_csr = suite_matrix(name, scale=scale)
        a_coo = a_csr.to_coo()
        b_coo = COO(a_coo.col, a_coo.row, a_coo.val,
                    (a_csr.shape[1], a_csr.shape[0]))  # A^T
        plan = spgemm_plan(a_coo, b_coo, tile=32, group=4, backend="jnp",
                           cache=PlanCache())
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=3)
        nnz_set = plan.report.nnz_a + plan.report.nnz_b
        for bsz in (1, 8, 32):
            av, bv = stream.values_batch_at(0, batch=bsz)

            def loop():
                return [plan.execute(av[i], bv[i]) for i in range(bsz)]

            def batched():
                return plan.execute_batch(av, bv)

            # Interleaved min-of-N: the two sides differ by tens of
            # percent, within scheduler noise for a lone 3-sample median —
            # alternating measurements and keeping the best of each side
            # compares like against like.
            loop(), batched()  # warm both jit caches
            loop_s, batch_s = float("inf"), float("inf")
            for _ in range(9):
                t0 = time.perf_counter()
                loop()
                loop_s = min(loop_s, time.perf_counter() - t0)
                t0 = time.perf_counter()
                batched()
                batch_s = min(batch_s, time.perf_counter() - t0)
            loop_ms, batch_ms = loop_s * 1e3, batch_s * 1e3
            vps = bsz * nnz_set / (batch_ms / 1e3)
            print(f"kernels,spgemm_batched_{name},{bsz},{nnz_set},"
                  f"{loop_ms:.1f},{batch_ms:.1f},{vps:.3e},"
                  f"{loop_ms / batch_ms:.2f}x")
        # Plan-cache observability (PlanCache.stats() via the report).
        cs = plan.report.as_dict()["cache_stats"]
        print(f"kernels,plan_cache_{name},hits={cs['hits']},"
              f"misses={cs['misses']},evictions={cs['evictions']},"
              f"resident_plans={cs['resident_plans']},"
              f"resident_bytes={cs['resident_bytes']}")

    data["pallas_batch"] = _pallas_batch_section()

    _persistence_section()

    if pipeline_depths:
        _pipeline_section(pipeline_depths)

    if devices > 1:
        _sharded_section(devices)
    return data


def _pallas_batch_section() -> dict:
    """Batch-folded Pallas grid: ``execute_batch`` on a pallas_interpret
    plan vs a loop of single-set Pallas calls — bitwise equality plus the
    dispatch amortization the fold buys. CI gates on the returned ``ok``
    (BENCH_kernel_schedule_metrics.json ``data.pallas_batch.ok``)."""
    print("kernels,pallas_batch_case,batch,loop_ms,batch_ms,speedup,bitwise")
    ad = random_block_sparse(256, 256, (32, 32), 0.35, seed=7)
    bd = random_block_sparse(256, 256, (32, 32), 0.35, seed=8)
    plan = spgemm_plan(ad, bd, tile=32, group=4,
                       backend="pallas_interpret", cache=PlanCache())
    stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=5)
    bsz = 4
    av, bv = stream.values_batch_at(0, batch=bsz)

    def loop():
        return [plan.execute(av[i], bv[i]) for i in range(bsz)]

    def batched():
        return plan.execute_batch(av, bv)

    ref, out = loop(), batched()  # also warms both jit caches
    bitwise = all(
        np.array_equal(np.asarray(r.todense()), np.asarray(o.todense()))
        for r, o in zip(ref, out)
    )
    loop_ms = timeit(loop, repeats=3, warmup=0) * 1e3
    batch_ms = timeit(batched, repeats=3, warmup=0) * 1e3
    rec = {
        "ok": bool(bitwise),
        "backend": "pallas_interpret",
        "batch": bsz,
        "num_triples": plan.report.num_triples,
        "loop_ms": loop_ms,
        "batch_ms": batch_ms,
        "speedup": loop_ms / batch_ms,
        "bitwise_equal": bool(bitwise),
    }
    print(f"kernels,spgemm_pallas_batch_256,{bsz},{loop_ms:.1f},"
          f"{batch_ms:.1f},{loop_ms / batch_ms:.2f}x,{bitwise}")
    if not bitwise:
        raise RuntimeError(
            "pallas batch grid diverged bitwise from looped execute")
    return rec


def _persistence_section() -> None:
    """Cold plan build (full symbolic phase) vs warm restart (verified
    disk load through the PlanCache disk tier) on the same pattern — the
    amortization REPRO_SPGEMM_PLAN_DIR buys a restarted serving worker."""
    print("kernels,persist_case,plan_file_kb,cold_plan_ms,warm_plan_ms,"
          "warm_speedup,schedule_builds_warm")
    for name, scale, tile, group in (
        ("poisson3Da", 0.02, 32, 4),
        ("2cubes_sphere", 0.003, 32, 4),
    ):
        a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
        b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))  # A^T
        with tempfile.TemporaryDirectory() as d:
            store = PlanStore(d)

            def cold():
                store.clear()  # every repeat pays the full symbolic phase
                return spgemm_plan(a, b, tile=tile, group=group,
                                   backend="jnp",
                                   cache=PlanCache(disk_dir=d))

            def warm():
                # Fresh cache on the populated directory = a restarted
                # process; only conversion-to-COO/digest/rebind host work.
                return spgemm_plan(a, b, tile=tile, group=group,
                                   backend="jnp",
                                   cache=PlanCache(disk_dir=d))

            cold_ms = timeit(cold, repeats=3, warmup=0) * 1e3
            cold()  # leave the store populated for the warm side
            plan = warm()
            if plan.report.schedule_builds != 0:
                raise RuntimeError("warm restart re-ran the symbolic phase")
            warm_ms = timeit(warm, repeats=3, warmup=0) * 1e3
            kb = store.total_bytes() / 1024
            print(f"kernels,spgemm_persist_{name},{kb:.0f},{cold_ms:.1f},"
                  f"{warm_ms:.1f},{cold_ms / warm_ms:.2f}x,"
                  f"{plan.report.schedule_builds}")


def _pipeline_section(depths=(1, 2, 4), steps: int = 24) -> None:
    """Streaming throughput: N serving steps (fresh values generated per
    step, one execute each) run synchronously vs through
    ``SpGEMMPipeline`` at several depths. The pipelined side overlaps
    value generation + staging (H2D + rebind) of step s+1 with step s's
    kernel and defers every D2H to collect — the paper's double-buffered
    operand fetch (depth 2) measured end to end. Results are
    bitwise-equal by construction (tests/test_pipeline.py)."""
    print("kernels,pipeline_case,depth,steps,sync_steps_s,pipe_steps_s,"
          "speedup")
    for name, scale, tile, group in (
        ("poisson3Da", 0.02, 32, 4),
        ("2cubes_sphere", 0.003, 32, 4),
    ):
        a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
        b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))  # A^T
        plan = spgemm_plan(a, b, tile=tile, group=group, backend="jnp",
                           cache=PlanCache())
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=3)

        def sync():
            return [plan.execute(*stream.values_at(s)) for s in range(steps)]

        def piped(depth):
            with plan.pipeline(depth=depth) as pipe:
                return list(pipe.stream(
                    stream.values_at(s) for s in range(steps)))

        # Interleaved min-of-N (same rationale as the batched section).
        sync()
        for d in depths:
            piped(d)  # warm the stage jits
        best = {"sync": float("inf")}
        best.update({d: float("inf") for d in depths})
        for _ in range(7):
            t0 = time.perf_counter()
            sync()
            best["sync"] = min(best["sync"], time.perf_counter() - t0)
            for d in depths:
                t0 = time.perf_counter()
                piped(d)
                best[d] = min(best[d], time.perf_counter() - t0)
        sync_sps = steps / best["sync"]
        for d in depths:
            pipe_sps = steps / best[d]
            print(f"kernels,spgemm_pipeline_{name},{d},{steps},"
                  f"{sync_sps:.1f},{pipe_sps:.1f},"
                  f"{pipe_sps / sync_sps:.2f}x")


def _sharded_section(devices: int) -> None:
    """Run the sharded benchmark in a subprocess with forced host devices
    (the XLA device count must be set before jax imports — this process
    already initialized the single-device backend)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"{env.get('XLA_FLAGS', '')} "
        f"--xla_force_host_platform_device_count={devices}"
    ).strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_kernels",
         "--sharded-worker", "--devices", str(devices)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=1200,
    )
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded benchmark worker failed:\n{out.stderr[-3000:]}"
        )


def _sharded_worker(devices: int) -> None:
    """Child process body: per-shard triple imbalance + values/s scaling
    of sharded execute_batch vs the single-device plan."""
    import jax

    from repro.launch.mesh import make_shard_mesh

    n_dev = len(jax.devices())
    print("kernels,sharded_case,shards,triples_max,triples_mean,"
          "imbalance,batch_ms,values_per_s,scaling_vs_1")
    shard_counts = [n for n in (2, 4, 8, 16) if n <= min(devices, n_dev)]
    for name, scale, tile, group, batch in _SHARDED_CASES:
        a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
        b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
        single = spgemm_plan(a, b, tile=tile, group=group, backend="jnp",
                             cache=PlanCache())
        stream = SpGEMMValueStream(single.a_pattern, single.b_pattern,
                                   seed=3)
        av, bv = stream.values_batch_at(0, batch=batch)
        nnz_set = single.report.nnz_a + single.report.nnz_b

        def best_of(plan, reps: int = 5) -> float:
            plan.execute_batch(av, bv)  # warm the jit
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                plan.execute_batch(av, bv)
                best = min(best, time.perf_counter() - t0)
            return best

        t1 = best_of(single)
        tmean = single.report.num_triples
        print(f"kernels,spgemm_sharded_{name},1,{tmean},{tmean:.1f},"
              f"1.00,{t1 * 1e3:.1f},{batch * nnz_set / t1:.3e},1.00x")
        for n in shard_counts:
            plan = spgemm_plan(a, b, tile=tile, group=group, backend="jnp",
                               cache=PlanCache(), mesh=make_shard_mesh(n))
            t = best_of(plan)
            st = plan.shard_stats()
            tmax = max(st["triples"])
            tmean = sum(st["triples"]) / n
            print(f"kernels,spgemm_sharded_{name},{n},{tmax},{tmean:.1f},"
                  f"{st['imbalance']:.2f},{t * 1e3:.1f},"
                  f"{batch * nnz_set / t:.3e},{t1 / t:.2f}x")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--devices", type=int, default=4,
                   help="forced host devices for the sharded section "
                        "(0/1 skips it)")
    p.add_argument("--pipeline-depth", type=str, default="1,2,4",
                   help="comma-separated SpGEMMPipeline depths for the "
                        "streaming-throughput section (empty/0 skips it)")
    p.add_argument("--sharded-worker", action="store_true",
                   help=argparse.SUPPRESS)  # internal: child process body
    args = p.parse_args(argv)
    depths = tuple(
        int(d) for d in args.pipeline_depth.split(",") if d.strip()
    )
    depths = tuple(d for d in depths if d > 0)
    if args.sharded_worker:
        _sharded_worker(args.devices)
        return None
    return run(devices=args.devices, pipeline_depths=depths)


if __name__ == "__main__":
    main()
