"""Kernel microbenchmarks (interpret/jnp on CPU — correctness-scale only;
wall-times here are NOT TPU numbers, the roofline report covers those).

Reports the schedule-level reuse metrics that determine TPU performance:
triples, B-fetch elision (block OMAR), and arithmetic intensity per kernel.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from repro.core.schedule import build_spgemm_schedule
from repro.kernels import ops
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.random import random_block_sparse


def run(quiet: bool = False):
    print("kernels,case,triples,b_fetches,block_omar_pct,flops,"
          "bytes_streamed,arith_intensity")
    for (m, k, n, da, db, g) in [
        (512, 512, 512, 0.2, 0.2, 2),
        (1024, 512, 1024, 0.1, 0.15, 4),
        (512, 1024, 512, 0.3, 0.3, 8),
    ]:
        bm = bk = bn = 128
        ad = random_block_sparse(m, k, (bm, bk), da, seed=1)
        bd = random_block_sparse(k, n, (bk, bn), db, seed=2)
        a = to_bcsv(ad, (bm, bk), group=g)
        b = to_bcsr(bd, (bk, bn))
        s = build_spgemm_schedule(a, b)
        flops = 2 * s.num_triples * bm * bk * bn
        # HBM bytes: A streamed once; B fetched per elided schedule; C
        # panels written once.
        bytes_ = (a.nnzb * bm * bk + s.b_fetches() * bk * bn
                  + s.n_panels * g * bm * bn) * 4
        ai = flops / bytes_
        print(f"kernels,spgemm_{m}x{k}x{n}_g{g},{s.num_triples},"
              f"{s.b_fetches()},{s.block_omar():.1f},{flops:.2e},"
              f"{bytes_:.2e},{ai:.1f}")

    # correctness spot (pallas interpret vs dense) as part of the bench
    ad = random_block_sparse(256, 256, (64, 64), 0.3, seed=3)
    bd = random_block_sparse(256, 256, (64, 64), 0.3, seed=4)
    c = ops.spgemm(to_bcsv(ad, (64, 64), 2), to_bcsr(bd, (64, 64)),
                   backend="pallas_interpret")
    err = np.abs(c.todense() - ad @ bd).max()
    print(f"kernels,spgemm_pallas_interpret_maxerr,{err:.2e}")


def main():
    run()


if __name__ == "__main__":
    main()
