"""Kernel microbenchmarks (interpret/jnp on CPU — correctness-scale only;
wall-times here are NOT TPU numbers, the roofline report covers those).

Reports the plan-level reuse metrics that determine TPU performance
(triples, B-fetch elision / block OMAR, arithmetic intensity) via the
plan/execute API, plus the amortization the API exists for: plan-build
time vs numeric-only execute time on the same pattern.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import timeit
from repro.data.pipeline import SpGEMMValueStream
from repro.kernels import ops
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.formats import COO
from repro.sparse.random import random_block_sparse, suite_matrix
from repro.spgemm import PlanCache, spgemm_plan


def run(quiet: bool = False):
    print("kernels,case,triples,b_fetches,block_omar_pct,flops,"
          "bytes_streamed,arith_intensity,plan_ms,execute_ms")
    for (m, k, n, da, db, g) in [
        (512, 512, 512, 0.2, 0.2, 2),
        (1024, 512, 1024, 0.1, 0.15, 4),
        (512, 1024, 512, 0.3, 0.3, 8),
    ]:
        bm = bk = bn = 128
        ad = random_block_sparse(m, k, (bm, bk), da, seed=1)
        bd = random_block_sparse(k, n, (bk, bn), db, seed=2)
        cache = PlanCache()

        def build_plan():
            cache.clear()
            return spgemm_plan(ad, bd, tile=(bm, bk, bn), group=g,
                               backend="jnp", cache=cache)

        plan = build_plan()
        rep = plan.report
        flops = 2 * rep.num_triples * bm * bk * bn
        # HBM bytes: A streamed once; B fetched per elided schedule; C
        # panels written once.
        bytes_ = (rep.nnzb_a * bm * bk + rep.b_fetches * bk * bn
                  + rep.n_panels * g * bm * bn) * 4
        ai = flops / bytes_
        # Amortization: full plan build (conversion + symbolic + staging)
        # vs numeric-only execute with fresh values on the cached plan.
        plan_ms = timeit(build_plan, repeats=3, warmup=0) * 1e3
        a_vals = plan.a_pattern.val * 0.5
        b_vals = plan.b_pattern.val * 2.0
        exec_ms = timeit(lambda: plan.execute(a_vals, b_vals),
                         repeats=3, warmup=1) * 1e3
        print(f"kernels,spgemm_{m}x{k}x{n}_g{g},{rep.num_triples},"
              f"{rep.b_fetches},{rep.block_omar:.1f},{flops:.2e},"
              f"{bytes_:.2e},{ai:.1f},{plan_ms:.1f},{exec_ms:.1f}")

    # Plan reuse correctness: fresh values on a cached plan match a fresh
    # dense reference (the serving loop's invariant).
    ad = random_block_sparse(256, 256, (64, 64), 0.3, seed=3)
    bd = random_block_sparse(256, 256, (64, 64), 0.3, seed=4)
    plan = spgemm_plan(ad, bd, tile=64, group=2,
                       backend="pallas_interpret", cache=PlanCache())
    c = plan.execute()
    err = np.abs(c.todense() - ad @ bd).max()
    print(f"kernels,spgemm_plan_interpret_maxerr,{err:.2e}")
    a2 = np.zeros_like(ad)
    a2[plan.a_pattern.row, plan.a_pattern.col] = plan.a_pattern.val * 3.0
    c2 = plan.execute(plan.a_pattern.val * 3.0, None)
    err2 = np.abs(c2.todense() - a2 @ bd).max()
    print(f"kernels,spgemm_plan_reexec_maxerr,{err2:.2e}")

    # Compatibility shim spot-check (ops.spgemm -> cached plan).
    c3 = ops.spgemm(to_bcsv(ad, (64, 64), 2), to_bcsr(bd, (64, 64)),
                    backend="pallas_interpret")
    err3 = np.abs(c3.todense() - ad @ bd).max()
    print(f"kernels,spgemm_ops_shim_maxerr,{err3:.2e}")

    # Batched numeric phase: one vmapped execute_batch call vs a loop of
    # single executes over the same value sets (C = A @ A^T on scaled paper
    # patterns, jnp backend — the serving workload shape).
    print("kernels,batched_case,batch,nnz_per_set,loop_ms,batch_ms,"
          "values_per_s,speedup")
    for name, scale in (("poisson3Da", 0.02), ("2cubes_sphere", 0.003)):
        a_csr = suite_matrix(name, scale=scale)
        a_coo = a_csr.to_coo()
        b_coo = COO(a_coo.col, a_coo.row, a_coo.val,
                    (a_csr.shape[1], a_csr.shape[0]))  # A^T
        plan = spgemm_plan(a_coo, b_coo, tile=32, group=4, backend="jnp",
                           cache=PlanCache())
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=3)
        nnz_set = plan.report.nnz_a + plan.report.nnz_b
        for bsz in (1, 8, 32):
            av, bv = stream.values_batch_at(0, batch=bsz)

            def loop():
                return [plan.execute(av[i], bv[i]) for i in range(bsz)]

            def batched():
                return plan.execute_batch(av, bv)

            # Interleaved min-of-N: the two sides differ by tens of
            # percent, within scheduler noise for a lone 3-sample median —
            # alternating measurements and keeping the best of each side
            # compares like against like.
            loop(), batched()  # warm both jit caches
            loop_s, batch_s = float("inf"), float("inf")
            for _ in range(9):
                t0 = time.perf_counter()
                loop()
                loop_s = min(loop_s, time.perf_counter() - t0)
                t0 = time.perf_counter()
                batched()
                batch_s = min(batch_s, time.perf_counter() - t0)
            loop_ms, batch_ms = loop_s * 1e3, batch_s * 1e3
            vps = bsz * nnz_set / (batch_ms / 1e3)
            print(f"kernels,spgemm_batched_{name},{bsz},{nnz_set},"
                  f"{loop_ms:.1f},{batch_ms:.1f},{vps:.3e},"
                  f"{loop_ms / batch_ms:.2f}x")


def main():
    run()


if __name__ == "__main__":
    main()
