"""Paper Table 8: STUF (spatial-temporal utilization factor).

U = N_ops / (F * P * R). We compute: (a) the paper's published STUF
reprinted; (b) our measured-CPU STUF from the measured runtime of the
vectorized Gustavson (the MKL-analogue); (c) the simulator-derived FPGA
STUF — cycles from the faithful FSpGEMMSimulator at SW=16/NUM_PE=32 give
R = cycles / F, independently of the paper's tables.
"""
from __future__ import annotations

from benchmarks.common import timeit
from repro.core.gustavson import FSpGEMMSimulator, gustavson_flops, spgemm_gustavson
from repro.core.perfmodel import (
    CPU_XEON_E5_2637,
    FPGA_ARRIA10,
    PAPER_MATRICES,
    PAPER_TABLE8_STUF,
    stuf,
)
from repro.sparse.convert import to_csv
from repro.sparse.random import suite_matrix


def run(scale: float = 0.02, sim_scale: float = 0.01, quiet: bool = False):
    print("stuf,matrix,ours_cpu(measured),fpga_sim(derived),paper_mkl,"
          "paper_cusparse,paper_fspgemm")
    rows = []
    for name in PAPER_MATRICES:
        a = suite_matrix(name, scale=scale)
        n_ops = gustavson_flops(a, a)
        r_cpu = timeit(spgemm_gustavson, a, a)
        u_cpu = stuf(n_ops, CPU_XEON_E5_2637, r_cpu)

        # Faithful simulator at the paper's operating point (smaller scale:
        # the element-level simulation is O(nnz expansion) in Python).
        a_s = suite_matrix(name, scale=sim_scale)
        csv = to_csv(a_s, 32)
        _, stats = FSpGEMMSimulator(32, 16).run(csv, a_s)
        r_fpga = stats.cycles / FPGA_ARRIA10.clock_Hz
        u_fpga = stuf(stats.flops, FPGA_ARRIA10, r_fpga)

        p = PAPER_TABLE8_STUF[name]
        rows.append((name, u_cpu, u_fpga))
        print(f"stuf,{name},{u_cpu:.2e},{u_fpga:.2e},{p['mkl']:.1e},"
              f"{p['cusparse']:.1e},{p['fspgemm']:.1e}")
    # Core claim: FSpGEMM's STUF beats CPU/GPU by ~6.3x / 14.7x on average.
    imp = [PAPER_TABLE8_STUF[n]["fspgemm"] / PAPER_TABLE8_STUF[n]["mkl"]
           for n in PAPER_MATRICES]
    print(f"stuf,paper_avg_improvement_vs_mkl,{sum(imp)/len(imp):.1f}"
          f" (paper reports 6.3x)")
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
