"""Paper Table 7: SpGEMM runtime comparison.

This container measures the CPU implementations (our vectorized Gustavson =
the MKL analogue, plus scipy's SpGEMM when available); the FPGA number is
modeled from the paper's Eq. 2 runtime model driven by the published STUF
(Table 8), with the paper's measured table reprinted alongside. Labels make
measured-vs-modeled explicit.
"""
from __future__ import annotations

from benchmarks.common import timeit
from repro.core.gustavson import gustavson_flops, spgemm_gustavson
from repro.core.perfmodel import (
    FPGA_ARRIA10,
    PAPER_MATRICES,
    PAPER_TABLE7_MS,
    PAPER_TABLE8_STUF,
    runtime_from_stuf,
)
from repro.sparse.random import suite_matrix


def run(scale: float = 0.05, quiet: bool = False):
    rows = []
    print("runtime,matrix,ours_cpu_ms(measured),scipy_ms(measured),"
          "fpga_ms(modeled@paper_stuf),paper_mkl_ms,paper_cusparse_ms,"
          "paper_fspgemm_ms")
    for name in PAPER_MATRICES:
        a = suite_matrix(name, scale=scale)
        ours = timeit(spgemm_gustavson, a, a) * 1e3
        try:
            sp = a.to_scipy()
            scipy_ms = timeit(lambda: sp @ sp) * 1e3
        except ImportError:
            scipy_ms = float("nan")
        n_ops = gustavson_flops(a, a)
        fpga_ms = runtime_from_stuf(
            n_ops, FPGA_ARRIA10, PAPER_TABLE8_STUF[name]["fspgemm"]) * 1e3
        p = PAPER_TABLE7_MS[name]
        rows.append((name, ours, scipy_ms, fpga_ms))
        print(f"runtime,{name},{ours:.2f},{scipy_ms:.2f},{fpga_ms:.3f},"
              f"{p['mkl']},{p['cusparse']},{p['fspgemm']}")
    # Scale-adjusted speedup estimate (work scales with nnz expansion).
    speedups = []
    for name, ours, _, fpga in rows:
        p = PAPER_TABLE7_MS[name]
        speedups.append(p["mkl"] / p["fspgemm"])
    gm = 1.0
    for s in speedups:
        gm *= s
    print(f"runtime,paper_avg_speedup_vs_cpu,{sum(speedups)/len(speedups):.2f}"
          f" (paper reports 4.9x)")
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
