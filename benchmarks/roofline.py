"""Roofline analysis (§Roofline): three terms per (arch x shape x mesh)
derived from the dry-run artifacts.

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / (links_per_chip * link_bw)

``compiled.cost_analysis()`` on the SPMD-partitioned module reports
*per-device* FLOPs/bytes; collective bytes are parsed from the per-device
HLO, so all three terms are per-chip seconds directly. Corrected values
(scan trip counts resolved, DESIGN.md Sec. 6) are used when present.

Hardware constants (TPU v5e-class): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI with ~3 usable links per chip on a 2D torus slice.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
LINKS = 3

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(art_dir: str = ART_DIR, mesh: str = "pod16x16") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cor = rec.get("corrected") or {}
    flops = cor.get("flops", rec["cost"]["flops"])
    bytes_ = cor.get("bytes", rec["cost"]["bytes"])
    coll = cor.get("collective_bytes")
    if coll is None:
        coll = sum(v["bytes"] for v in rec.get("collectives", {}).values())
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = coll / (LINKS * LINK_BW)
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    chips = 1
    for v in rec.get("mesh", {}).values():
        chips *= v
    model_per_chip = rec.get("model", {}).get("model_flops", 0.0) / max(chips, 1)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "microbatches": rec.get("microbatches", 1),
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "bound_s": max(t_c, t_m, t_x),
        "roofline_fraction": t_c / max(t_c, t_m, t_x) if max(t_c, t_m, t_x) else 0.0,
        "model_flops_per_chip": model_per_chip,
        "useful_ratio": model_per_chip / flops if flops else 0.0,
        "peak_GiB": rec["memory"]["peak_bytes"] / 2**30,
        "peak_GiB_tpu_adj": rec["memory"].get(
            "peak_bytes_tpu_adjusted", rec["memory"]["peak_bytes"]) / 2**30,
        "hlo_flops": flops,
        "hlo_bytes": bytes_,
        "coll_bytes": coll,
    }


_SUGGEST = {
    "compute": "compute-bound: raise MXU utilization (larger tiles, fewer "
               "pad-wasted heads) or shrink redundant recompute",
    "memory": "HBM-bound: raise arithmetic intensity (fuse, larger "
              "microbatch, cache-resident accumulation, bf16 end-to-end)",
    "collective": "ICI-bound: cut wire bytes (bf16/int8 reductions, "
                  "hierarchical pod reduction) or overlap with compute",
}


def report(art_dir: str = ART_DIR, mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    print("roofline,arch,shape,u,compute_s,memory_s,collective_s,dominant,"
          "roofline_frac,useful_ratio,peak_GiB,peak_GiB_adj")
    for rec in load_cells(art_dir, mesh):
        t = terms(rec)
        if t is None:
            print(f"roofline,{rec['arch']},{rec['shape']},-,-,-,-,"
                  f"{rec.get('status')},-,-,-,-")
            continue
        rows.append(t)
        print(
            f"roofline,{t['arch']},{t['shape']},{t['microbatches']},"
            f"{t['compute_s']:.3e},{t['memory_s']:.3e},"
            f"{t['collective_s']:.3e},{t['dominant']},"
            f"{t['roofline_fraction']:.3f},{t['useful_ratio']:.3f},"
            f"{t['peak_GiB']:.2f},{t['peak_GiB_tpu_adj']:.2f}"
        )
    if rows:
        for dom in ("compute", "memory", "collective"):
            n = sum(1 for r in rows if r["dominant"] == dom)
            print(f"roofline,summary,{dom}_bound_cells,{n}")
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        print(f"roofline,summary,worst_fraction,{worst['arch']},"
              f"{worst['shape']},{worst['roofline_fraction']:.3f}")
        print(f"roofline,hint,{_SUGGEST[worst['dominant']]}")
    return rows


def main():
    return report()


if __name__ == "__main__":
    main()
