"""Static-verifier cost: what does ``validate="deep"`` add to a plan?

Builds element and block plans for (scaled) Table 4 matrices and times
:func:`repro.analysis.verify.verify_plan` plus the kernel-spec lint —
the exact work ``spgemm_plan(..., validate="deep")`` performs at every
plan-return and rehydrate point. The section's value is the overhead
ratio: verification is pure host-side numpy over the symbolic schedule,
so it must stay a small fraction of the symbolic build it guards (the
record carries both times, and the overhead fraction is the tracked
trajectory). CI gates on ``ok`` = every plan verifies clean with no
kernel-lint errors; the timings are informational (shared runners are
too jittery to gate a few-millisecond ratio).

``PYTHONPATH=src python -m benchmarks.bench_verify [--scale S]``
"""
from __future__ import annotations

import argparse
import time

from repro.analysis.kernel_lint import lint_plan_kernel_specs
from repro.analysis.verify import verify_plan
from repro.sparse.convert import bcsr_from_coo, bcsv_from_coo
from repro.sparse.formats import COO
from repro.sparse.random import suite_matrix
from repro.spgemm import PlanCache, spgemm_plan

# Smallest two Table 4 matrices at a CI-friendly scale; A @ A^T like the
# paper's benchmark harness.
MATRICES = [("poisson3Da", 0.02), ("2cubes_sphere", 0.004)]


def _operands(name: str, scale: float):
    a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
    b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
    return a, b


def _best_ms(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def run(scale: float = 1.0, tile: int = 16, group: int = 2,
        backend: str = "jnp", repeats: int = 3, quiet: bool = False):
    rows = []
    for name, base_scale in MATRICES:
        a, b = _operands(name, base_scale * scale)
        a_bcsv, _ = bcsv_from_coo(a, (tile, tile), group)
        b_bcsr, _ = bcsr_from_coo(b, (tile, tile))
        for kind, build in (
            ("element", lambda: spgemm_plan(
                a, b, tile=tile, group=group, backend=backend,
                cache=PlanCache())),
            ("block", lambda: spgemm_plan(
                a_bcsv, b_bcsr, backend=backend, cache=PlanCache())),
        ):
            t0 = time.perf_counter()
            plan = build()
            build_ms = (time.perf_counter() - t0) * 1e3
            report = verify_plan(plan)
            lint = lint_plan_kernel_specs(plan)
            verify_ms = _best_ms(lambda: verify_plan(plan), repeats)
            rows.append({
                "matrix": name,
                "kind": kind,
                "nnz": int(a.nnz),
                "num_triples": int(plan.report.num_triples),
                "checks": len(report.checks_run),
                "findings": len(report.findings),
                "lint_errors": sum(1 for f in lint
                                   if f.severity == "error"),
                "ok": report.ok,
                "build_ms": build_ms,
                "verify_ms": verify_ms,
                "overhead_frac": verify_ms / build_ms if build_ms else None,
            })
    ok = all(r["ok"] and not r["lint_errors"] for r in rows)
    if not quiet:
        print("matrix,kind,nnz,triples,checks,findings,"
              "build_ms,verify_ms,overhead")
        for r in rows:
            print(f"{r['matrix']},{r['kind']},{r['nnz']},"
                  f"{r['num_triples']},{r['checks']},{r['findings']},"
                  f"{r['build_ms']:.1f},{r['verify_ms']:.1f},"
                  f"{r['overhead_frac']:.2f}")
        print(f"ok={ok} (gate: clean verify + no kernel-lint errors)")
    return {"rows": rows, "ok": ok}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="extra scale factor on the per-matrix defaults")
    ap.add_argument("--tile", type=int, default=16)
    ap.add_argument("--group", type=int, default=2)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    return run(scale=args.scale, tile=args.tile, group=args.group,
               backend=args.backend, repeats=args.repeats)


if __name__ == "__main__":
    main()
