"""Batch-fusion knee calibration (the measured basis of
``repro.spgemm.executor._CHUNK_POLICY``).

Runs :func:`repro.core.tuning.measure_chunk_knee` on the current backend:
plans of growing per-set working bytes, each timed as one fused
``run_batch`` call vs. one call per element, plus a chunk-size sweep on the
smallest case for the ``cache_bytes`` knob. The reported ``knee_bytes`` is
the number that belongs in the policy table's row for this backend — CPU
in CI; run the same module on a TPU/GPU host to re-measure those rows
(or override per process with ``REPRO_SPGEMM_CHUNK_BYTES``).

``PYTHONPATH=src python -m benchmarks.bench_chunk_knee [--batch N]``
"""
from __future__ import annotations

import argparse

from repro.core.tuning import measure_chunk_knee


def run(batch: int = 8, repeats: int = 3, backend: str = "jnp",
        quiet: bool = False):
    res = measure_chunk_knee(batch=batch, repeats=repeats, backend=backend)
    if not quiet:
        print(f"device={res['device_backend']} plan_backend={backend} "
              f"batch={batch}")
        print("chunk_knee,per_set_bytes,fused_ms_per_set,split_ms_per_set,"
              "speedup")
        for s in res["samples"]:
            print(f"{s['case']},{s['per_set_bytes']},"
                  f"{s['fused_ms_per_set']:.3f},{s['split_ms_per_set']:.3f},"
                  f"{s['speedup']:.2f}")
        print("chunk_sweep,chunk,working_bytes,ms_per_set")
        for c in res["chunk_sweep"]:
            print(f"chunk_sweep,{c['chunk']},{c['working_bytes']},"
                  f"{c['ms_per_set']:.3f}")
        print(f"knee_bytes={res['knee_bytes']} "
              f"suggested_policy_row={res['suggested_policy_row']} "
              f"configured_policy_row={res['configured_policy_row']}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--backend", default="jnp",
                    help="plan backend to calibrate (jnp here matches the "
                         "policy's CPU row; pallas on a real TPU)")
    args = ap.parse_args(argv)
    return run(batch=args.batch, repeats=args.repeats, backend=args.backend)


if __name__ == "__main__":
    main()
