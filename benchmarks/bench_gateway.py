"""Gateway serving benchmark: multi-tenant throughput, latency, batching.

Drives the multi-tenant gateway (``repro.spgemm.gateway``) with a bursty
two-pattern workload and reports, per pattern: sustained requests/s,
p50/p99 latency, micro-batch fill (requests per pipeline dispatch — the
headline should be > 1 under bursts), and shed counts. A second phase
shrinks the in-flight byte budget to show overload shedding as typed
outcomes rather than hangs.

Results are verified on the way out: every admitted request's CSR must be
bitwise-equal to a direct ``plan.execute`` of the same values.
"""
from __future__ import annotations

import time

import numpy as np

from repro.data.pipeline import SpGEMMValueStream
from repro.sparse.random import random_coo
from repro.spgemm import Outcome, PlanCache, SpGEMMGateway


def _pattern(seed, m, k, n, density=0.06):
    a = random_coo(m, k, density, "uniform", seed=seed).sum_duplicates()
    b = random_coo(k, n, density, "uniform", seed=seed + 1).sum_duplicates()
    return a, b


def _assert_same_csr(x, y):
    assert np.array_equal(x.indptr, y.indptr)
    assert np.array_equal(x.indices, y.indices)
    assert np.array_equal(x.data, y.data)


def _drive(gw, plans, streams, bursts, burst_size, verify=4):
    """Submit `bursts` rounds of `burst_size` same-instant requests per
    pattern, wait for all, and bitwise-check a sample."""
    tickets = []
    step = 0
    for _ in range(bursts):
        for tok in plans:
            for _ in range(burst_size):
                a, b = streams[tok].values_at(step)
                tickets.append((tok, step, gw.submit(tok, a, b)))
                step += 1
        time.sleep(0.001)  # burst gap: lets the window close per burst
    results = [(tok, s, t.wait(timeout=300)) for tok, s, t in tickets]
    ok = [r for r in results if r[2].outcome is Outcome.OK]
    for tok, s, res in ok[:verify] + ok[-verify:]:
        _assert_same_csr(plans[tok].execute(*streams[tok].values_at(s)),
                         res.value)
    return results


def run(quiet: bool = False, bursts: int = 6, burst_size: int = 8):
    cache = PlanCache()
    gw = SpGEMMGateway(cache=cache, max_pipelines=2, depth=2, max_batch=8,
                       batch_window=0.002)
    plans = {
        "tenant0/p96": gw.register("tenant0/p96", *_pattern(0, 96, 72, 80),
                                   tile=8, group=2, backend="jnp"),
        "tenant1/p64": gw.register(
            "tenant1/p64", *_pattern(4, 64, 64, 64, 0.08),
            tile=8, group=2, backend="jnp"),
    }
    streams = {
        tok: SpGEMMValueStream(p.a_pattern, p.b_pattern, seed=7 + i)
        for i, (tok, p) in enumerate(plans.items())
    }
    # Warm the jit caches (batch-size-dependent programs) off the clock.
    _drive(gw, plans, streams, bursts=2, burst_size=burst_size, verify=0)
    gw.drain(timeout=60)

    t0 = time.perf_counter()
    results = _drive(gw, plans, streams, bursts, burst_size)
    elapsed = time.perf_counter() - t0
    stats = gw.stats()

    n_ok = sum(1 for _, _, r in results if r.outcome is Outcome.OK)
    out = {"elapsed_s": elapsed, "requests_ok": n_ok,
           "throughput_rps": n_ok / elapsed, "patterns": {}}
    print("gateway,pattern,requests,dispatches,batch_fill,p50_ms,p99_ms,"
          "throughput_rps,shed")
    for tok in plans:
        ps = stats["patterns"][tok]
        lat = ps["latency_s"]
        out["patterns"][tok] = {
            "completed": ps["completed"],
            "dispatches": ps["dispatches"],
            "batch_fill": ps["batch_fill"],
            "p50_ms": lat["p50"] * 1e3,
            "p99_ms": lat["p99"] * 1e3,
            "throughput_rps": ps["throughput_rps"],
            "shed_total": ps["shed_total"],
        }
        print(f"gateway,{tok},{ps['completed']},{ps['dispatches']},"
              f"{ps['batch_fill']:.2f},{lat['p50'] * 1e3:.2f},"
              f"{lat['p99'] * 1e3:.2f},{ps['throughput_rps']:.1f},"
              f"{ps['shed_total']}")
        assert ps["batch_fill"] > 1.0, (
            f"bursty load must batch: fill={ps['batch_fill']}")
    gw.close()

    # Overload phase: a budget that admits ~2 requests' bytes sheds the
    # rest as typed outcomes — nothing hangs, admitted work completes.
    tok = "tenant0/p96"
    plan = plans[tok]
    gw2 = SpGEMMGateway(cache=cache, max_pipelines=1, max_batch=4,
                        max_inflight_bytes=2 * plan.value_nbytes() + 16,
                        start=False)
    gw2.register_plan(tok, plan)
    tickets = [gw2.submit(tok, *streams[tok].values_at(s)) for s in range(8)]
    shed_now = sum(1 for t in tickets if t.done())
    gw2.start()
    done = [t.wait(timeout=300) for t in tickets]
    gw2.close()
    sheds = {}
    for r in done:
        if r.outcome is not Outcome.OK:
            sheds[r.outcome.value] = sheds.get(r.outcome.value, 0) + 1
    out["overload"] = {
        "submitted": len(tickets), "shed_at_admission": shed_now,
        "completed": sum(1 for r in done if r.outcome is Outcome.OK),
        "sheds": sheds,
    }
    print(f"gateway,overload,submitted={len(tickets)},"
          f"ok={out['overload']['completed']},shed={sheds}")
    assert shed_now > 0 and sheds.get("shed_bytes", 0) == shed_now
    return out


def main():
    return run()


if __name__ == "__main__":
    main()
